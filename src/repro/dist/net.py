"""TCP socket transport for the master-worker harness.

The pipe transport (``transport.WorkerLink``) stays the default; this
module is the network backend behind the same link surface, built for
the failure class the paper's Lambda deployment actually exhibits —
*network* trouble, not just slow compute:

* **Framing**: every message is one length-prefixed frame —
  ``MAGIC | payload_len | mid | ts | crc32`` header (:data:`_HEADER`,
  network byte order) followed by the pickled payload.  The CRC covers
  mid + ts + payload, so a corrupted or truncated stream is *detected*,
  never silently mis-parsed.
* **Idempotent resend**: ``mid`` is a per-sender monotonically
  increasing message id.  A sender that hits a socket error retransmits
  the SAME frame after reconnecting; the receiver's :class:`MidFilter`
  drops the duplicate, so at-least-once delivery looks exactly-once to
  the protocol layer.
* **Timestamps**: ``ts`` is the sender's ``perf_counter`` at frame
  encode time (one host, one monotonic base — the same clock contract
  the rest of the telemetry relies on), giving per-message wire
  latency on both directions.
* **Handshake + reconnect**: a connecting worker leads with a
  ``__hello__`` frame carrying its worker id and *incarnation* (its
  respawn count).  :class:`TcpHost` attaches the socket to the
  registered link — unless the incarnation is stale (smaller than the
  link's), in which case the socket is refused: a zombie predecessor
  can never speak for its replacement (split-brain safety; the master
  stays the sole gate authority).  :class:`NetConnection` reconnects
  with bounded exponential backoff and re-runs the hello each time.
* **Fault enactment**: the master-side :class:`TcpWorkerLink` enacts
  :class:`~repro.dist.injection.NetFaultSpec` network faults — one-way
  / two-way partitions (incoming frames buffered like a backed-up TCP
  queue and flushed on heal; two-way also swallows outgoing sends),
  added latency with jitter, probabilistic drop / duplicate / reorder.
  Faults apply *below* the mid filter, so an injected duplicate
  genuinely exercises the dedup path.

``docs/fault_tolerance.md`` ("Network transport & partitions") has the
wire format and the partition-vs-death state machine this backend
feeds.
"""

from __future__ import annotations

import io
import pickle
import socket
import struct
import threading
import time
import zlib

import numpy as np

MAGIC = b"SG"
_HEADER = struct.Struct("!2sIQdI")   # magic, payload_len, mid, ts, crc32
MAX_FRAME = 64 * 1024 * 1024         # sanity bound on payload_len

HELLO_KIND = "__hello__"


class FrameError(ValueError):
    """Corrupted stream: bad magic, oversized length, CRC mismatch, or
    an undecodable / forbidden payload."""


#: builtins a wire payload may name — plain data constructors only.
_SAFE_BUILTINS = frozenset({
    "bool", "int", "float", "complex", "str", "bytes", "bytearray",
    "list", "tuple", "dict", "set", "frozenset", "slice", "range",
})

#: numpy's array/scalar pickle-reconstruction entry points moved from
#: ``numpy.core`` to ``numpy._core`` in numpy 2.x; accept both so a
#: frame encoded by either generation decodes.
_NUMPY_RECON_MODULES = frozenset({
    "numpy.core.multiarray", "numpy._core.multiarray",
})

_NUMPY_SCALARS = frozenset({
    "bool_", "int8", "int16", "int32", "int64", "intp",
    "uint8", "uint16", "uint32", "uint64", "uintp",
    "float16", "float32", "float64", "longdouble",
    "complex64", "complex128", "clongdouble",
    "datetime64", "timedelta64", "str_", "bytes_",
})


class _RestrictedUnpickler(pickle.Unpickler):
    """Unpickler whose ``find_class`` allowlists plain-data builtins and
    numpy array/scalar reconstruction — nothing else.  A TCP frame is a
    trust boundary: a payload naming any other global (``os.system``,
    ``subprocess.*``, arbitrary ``__reduce__`` gadgets) raises
    :class:`FrameError` before any constructor runs."""

    def find_class(self, module: str, name: str):
        if module == "builtins" and name in _SAFE_BUILTINS:
            return super().find_class(module, name)
        if module in _NUMPY_RECON_MODULES and name in (
            "_reconstruct", "scalar",
        ):
            return super().find_class(module, name)
        if module == "numpy" and (
            name in ("ndarray", "dtype") or name in _NUMPY_SCALARS
        ):
            return super().find_class(module, name)
        if module == "numpy.dtypes" and name.endswith("DType"):
            return super().find_class(module, name)
        raise FrameError(
            f"wire payload references forbidden global {module}.{name}"
        )


def safe_loads(payload: bytes):
    """Deserialize one wire payload through the restricted unpickler.

    Every failure mode — forbidden global, truncated pickle stream,
    structurally bogus opcodes — surfaces as :class:`FrameError`, the
    same class the framing layer raises, so callers have exactly one
    "this peer is speaking garbage" path (drop the socket, let the
    reconnect/partition machinery take over)."""
    try:
        return _RestrictedUnpickler(io.BytesIO(payload)).load()
    except FrameError:
        raise
    except (pickle.UnpicklingError, EOFError, AttributeError, ImportError,
            IndexError, KeyError, MemoryError, TypeError, ValueError,
            struct.error) as exc:
        raise FrameError(f"undecodable wire payload: {exc!r}") from exc


def frame_crc(payload: bytes, mid: int, ts: float) -> int:
    return zlib.crc32(payload, zlib.crc32(struct.pack("!Qd", mid, ts)))


def encode_frame(payload: bytes, mid: int, ts: float) -> bytes:
    """One wire frame: header + raw payload bytes."""
    if len(payload) > MAX_FRAME:
        raise FrameError(f"payload {len(payload)} exceeds {MAX_FRAME}")
    crc = frame_crc(payload, mid, ts)
    return _HEADER.pack(MAGIC, len(payload), mid, ts, crc) + payload


class FrameDecoder:
    """Incremental frame parser over an arbitrary byte stream.

    ``feed(data)`` returns every complete ``(payload, mid, ts)`` frame
    the buffer now holds; partial frames wait for more bytes.  A bad
    magic or CRC raises :class:`FrameError` — the stream is
    unrecoverable past that point (framing is lost), so callers drop
    the connection and let the reconnect/resend layer recover.
    """

    def __init__(self):
        self._buf = bytearray()

    @property
    def pending_bytes(self) -> int:
        return len(self._buf)

    def feed(self, data: bytes) -> list[tuple[bytes, int, float]]:
        self._buf.extend(data)
        out = []
        while True:
            if len(self._buf) < _HEADER.size:
                return out
            magic, length, mid, ts, crc = _HEADER.unpack_from(self._buf)
            if magic != MAGIC:
                raise FrameError(f"bad magic {bytes(magic)!r}")
            if length > MAX_FRAME:
                raise FrameError(f"frame length {length} exceeds bound")
            end = _HEADER.size + length
            if len(self._buf) < end:
                return out
            payload = bytes(self._buf[_HEADER.size:end])
            del self._buf[:end]
            if frame_crc(payload, mid, ts) != crc:
                raise FrameError(f"crc mismatch on mid {mid}")
            out.append((payload, mid, ts))


class MidFilter:
    """Duplicate suppression on monotonically increasing message ids.

    ``accept(mid)`` is True exactly once per id.  Ids at or below the
    contiguous low-water mark are rejected outright; a bounded set
    tracks the (reordered) ids above it, so memory stays O(window) even
    on a long run."""

    def __init__(self):
        self._floor = 0          # every mid <= floor already accepted
        self._seen: set[int] = set()

    def accept(self, mid: int) -> bool:
        if mid <= self._floor or mid in self._seen:
            return False
        self._seen.add(mid)
        while self._floor + 1 in self._seen:
            self._floor += 1
            self._seen.discard(self._floor)
        return True


# ---------------------------------------------------------------------------
# worker side: NetConnection (duck-types multiprocessing.Connection)
# ---------------------------------------------------------------------------


class NetConnection:
    """Worker-side endpoint: the subset of ``mp.Connection`` that
    ``worker_main`` uses (``send`` / ``recv`` / ``poll`` / ``close``)
    over one TCP socket, with transparent reconnect.

    * ``send`` pickles into a frame (stamping ``msg["_sent"]`` for the
      wire-telemetry split) and retransmits the SAME frame after a
      reconnect — the host-side mid filter makes that idempotent.
    * ``recv`` / ``poll`` parse frames off the socket, dedup by mid,
      and remember the last frame's master->worker wire lag.
    * Reconnects are bounded exponential backoff; exhaustion raises
      ``EOFError`` (what ``worker_main`` treats as "master gone").
    """

    def __init__(self, addr, worker_id: int, incarnation: int = 0, *,
                 connect_timeout: float = 10.0, max_retries: int = 6,
                 backoff_s: float = 0.05, backoff_max_s: float = 2.0):
        self.addr = tuple(addr)
        self.worker_id = int(worker_id)
        self.incarnation = int(incarnation)
        self.connect_timeout = connect_timeout
        self.max_retries = max_retries
        self.backoff_s = backoff_s
        self.backoff_max_s = backoff_max_s
        self._sock: socket.socket | None = None
        self._decoder = FrameDecoder()
        self._filter = MidFilter()
        self._inbox: list[dict] = []
        self._mid = 0
        self._closed = False
        self.last_wire_lag: float | None = None
        self._connect()

    # -- wire ------------------------------------------------------------
    def _connect(self) -> None:
        """(Re)establish the socket and lead with the hello frame."""
        last_exc: Exception | None = None
        for attempt in range(self.max_retries + 1):
            if attempt:
                time.sleep(min(self.backoff_s * (2.0 ** (attempt - 1)),
                               self.backoff_max_s))
            try:
                sock = socket.create_connection(
                    self.addr, timeout=self.connect_timeout
                )
                sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
                hello = pickle.dumps({
                    "kind": HELLO_KIND,
                    "worker": self.worker_id,
                    "incarnation": self.incarnation,
                })
                self._mid += 1
                sock.sendall(encode_frame(hello, self._mid,
                                          time.perf_counter()))
                sock.settimeout(None)
                self._sock = sock
                self._decoder = FrameDecoder()
                return
            except OSError as exc:
                last_exc = exc
        self._sock = None
        raise EOFError(f"cannot reach master at {self.addr}: {last_exc}")

    def send(self, msg: dict) -> None:
        if self._closed:
            raise OSError("connection closed")
        msg = dict(msg)
        msg["_sent"] = time.perf_counter()
        self._mid += 1
        frame = encode_frame(pickle.dumps(msg), self._mid,
                             msg["_sent"])
        for attempt in range(2):
            try:
                if self._sock is None:
                    self._connect()
                self._sock.sendall(frame)
                return
            except OSError:
                self._drop_socket()
                if attempt:
                    raise
        raise OSError("send failed after reconnect")

    def _drop_socket(self) -> None:
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass
        self._sock = None

    def _pump(self, timeout: float | None) -> bool:
        """Read whatever the socket has (blocking up to ``timeout``)
        into the inbox; True if the inbox is non-empty afterwards."""
        if self._inbox:
            return True
        if self._sock is None:
            self._connect()
        try:
            self._sock.settimeout(timeout)
            data = self._sock.recv(65536)
        except (TimeoutError, socket.timeout):
            return False
        except OSError:
            self._drop_socket()
            return False
        finally:
            if self._sock is not None:
                try:
                    self._sock.settimeout(None)
                except OSError:
                    pass
        if not data:                     # orderly EOF from the master
            self._drop_socket()
            raise EOFError("master closed the connection")
        try:
            frames = self._decoder.feed(data)
        except FrameError:
            self._drop_socket()          # framing lost: force reconnect
            return False
        now = time.perf_counter()
        for payload, mid, ts in frames:
            if not self._filter.accept(mid):
                continue
            try:
                msg = safe_loads(payload)
            except FrameError:
                self._drop_socket()      # hostile/garbled payload: same
                return False             # path as a framing loss
            self.last_wire_lag = now - ts
            self._inbox.append(msg)
        return bool(self._inbox)

    # -- mp.Connection surface -------------------------------------------
    def poll(self, timeout: float = 0.0):
        if self._closed:
            raise OSError("connection closed")
        if self._inbox:
            return True
        try:
            return self._pump(timeout if timeout > 0 else 0.0001)
        except EOFError:
            return True                  # let recv raise the EOF

    def recv(self) -> dict:
        if self._closed:
            raise OSError("connection closed")
        while not self._inbox:
            self._pump(0.25)
        return self._inbox.pop(0)

    def close(self) -> None:
        self._closed = True
        self._drop_socket()


def tcp_child_main(spec: tuple, target, setup) -> None:
    """Spawn shim: build the worker's :class:`NetConnection` from the
    picklable ``spec`` and hand it to the normal worker target."""
    addr, worker_id, incarnation = spec
    try:
        conn = NetConnection(addr, worker_id, incarnation)
    except EOFError:
        return                           # master was gone before we started
    try:
        target(conn, setup)
    finally:
        conn.close()


# ---------------------------------------------------------------------------
# master side: TcpHost + TcpWorkerLink
# ---------------------------------------------------------------------------


class TcpWorkerLink:
    """Master-side handle on one TCP worker: the ``WorkerLink`` surface
    plus reconnect-awareness and network-fault enactment.

    Unlike the pipe link, losing the socket does NOT mark the link
    broken: the process may be alive behind a partition and the host
    will re-attach its reconnect.  ``peer_alive()`` is what separates
    *partitioned* from *dead* for the supervisor."""

    reconnectable = True

    def __init__(self, worker_id: int, *, incarnation: int = 0,
                 fault=None, seed: int = 0):
        self.worker_id = worker_id
        self.process = None
        self.incarnation = int(incarnation)
        self.broken = False
        self.fault = fault
        self._rng = np.random.default_rng(
            [seed, getattr(fault, "seed", 0) or 0, worker_id, 0x0e7]
        )
        self._lock = threading.Lock()
        self._sock: socket.socket | None = None
        self._decoder = FrameDecoder()
        self._filter = MidFilter()
        self._mid = 0
        self._seq = 0
        self._queue: list[tuple[float, int, int, float, dict]] = []
        self._preload: list[tuple[bytes, int, float]] = []
        self._held: list[tuple[int, float, dict]] = []
        self._round = 0
        self._partition_t0: float | None = None
        self._was_partitioned = False

    # -- partition bookkeeping -------------------------------------------
    def set_round(self, t: int) -> None:
        self._round = int(t)

    def _partition_active(self, now: float) -> bool:
        f = self.fault
        if f is None or f.partition_round is None:
            return False
        if self._round < f.partition_round:
            return False
        if self._partition_t0 is None:
            self._partition_t0 = now
            self._was_partitioned = True
        if f.heal_after_s is not None:
            return now - self._partition_t0 < f.heal_after_s
        return self._round < f.partition_round + f.partition_rounds

    # -- socket attach (host accept thread) ------------------------------
    def attach(self, sock: socket.socket, *,
               decoder: FrameDecoder | None = None,
               pending: list[tuple[bytes, int, float]] = ()) -> None:
        """Adopt a freshly-handshaken socket.  The handshake may have
        read past the hello — its decoder (holding any partial frame)
        and already-parsed extra frames carry over so nothing the
        worker pipelined behind the hello is lost."""
        with self._lock:
            old, self._sock = self._sock, sock
            self._decoder = decoder if decoder is not None else FrameDecoder()
            self._preload.extend(pending)
        if old is not None:
            try:
                old.close()
            except OSError:
                pass

    def _detach(self) -> None:
        with self._lock:
            sock, self._sock = self._sock, None
        if sock is not None:
            try:
                sock.close()
            except OSError:
                pass

    # -- WorkerLink surface ----------------------------------------------
    def alive(self) -> bool:
        return (not self.broken and self.process is not None
                and self.process.is_alive())

    def peer_alive(self) -> bool:
        """The worker *process* is up, whether or not we can reach it —
        the discriminator between a partition and a death."""
        return self.process is not None and self.process.is_alive()

    def waitable(self):
        return self._sock

    def send(self, msg: dict) -> bool:
        if self.broken:
            return False
        now = time.perf_counter()
        f = self.fault
        if self._partition_active(now) and f.partition_mode == "twoway":
            return True                  # swallowed by the partition
        if f is not None and f.drop_p > 0 \
                and self._rng.random() < f.drop_p:
            return True                  # lost on the wire
        msg = dict(msg)
        msg["_sent"] = time.perf_counter()
        self._mid += 1
        frame = encode_frame(pickle.dumps(msg), self._mid, msg["_sent"])
        with self._lock:
            sock = self._sock
        if sock is None:
            return False
        try:
            sock.sendall(frame)
            return True
        except OSError:
            self._detach()               # unreachable, not (yet) dead
            return False

    def _intake(self, msg: dict, mid: int, ts: float) -> None:
        """Fault layer between the wire and delivery (dedup happens at
        delivery, so injected duplicates exercise the mid filter)."""
        now = time.perf_counter()
        f = self.fault
        if self._partition_active(now):
            self._held.append((mid, ts, msg))
            return
        copies = 1
        if f is not None:
            if f.drop_p > 0 and self._rng.random() < f.drop_p:
                return
            if f.dup_p > 0 and self._rng.random() < f.dup_p:
                copies = 2
        for _ in range(copies):
            due = now
            if f is not None:
                if f.latency_s > 0 or f.latency_jitter_s > 0:
                    due += f.latency_s + f.latency_jitter_s * float(
                        self._rng.random()
                    )
                if f.reorder_p > 0 and self._rng.random() < f.reorder_p:
                    due += f.reorder_hold_s
            self._seq += 1
            self._queue.append((due, self._seq, mid, ts, msg))

    def _pump(self) -> None:
        """Drain the socket non-blockingly into the fault queue."""
        with self._lock:
            sock = self._sock
            preload, self._preload = self._preload, []
        for payload, mid, ts in preload:
            try:
                msg = safe_loads(payload)
            except FrameError:
                self._detach()           # poisoned handshake backlog
                return
            self._intake(msg, mid, ts)
        if sock is not None:
            while True:
                try:
                    sock.settimeout(0.0)
                    data = sock.recv(65536)
                except (BlockingIOError, TimeoutError, socket.timeout):
                    break
                except OSError:
                    self._detach()
                    break
                finally:
                    try:
                        sock.settimeout(None)
                    except OSError:
                        pass
                if not data:             # peer closed its end
                    self._detach()
                    break
                try:
                    frames = self._decoder.feed(data)
                    for payload, mid, ts in frames:
                        self._intake(safe_loads(payload), mid, ts)
                except FrameError:
                    self._detach()       # framing/payload lost: await
                    break                # reconnect
        # a healed partition flushes the held frames in order, like a
        # backed-up TCP buffer finally delivering
        if self._held and not self._partition_active(time.perf_counter()):
            held, self._held = self._held, []
            for mid, ts, msg in held:
                self._intake(msg, mid, ts)

    def try_recv(self) -> dict | None:
        if self.broken:
            return None
        self._pump()
        now = time.perf_counter()
        due = [k for k, item in enumerate(self._queue) if item[0] <= now]
        while due:
            k = min(due, key=lambda j: self._queue[j][0])
            _, _, mid, ts, msg = self._queue.pop(k)
            if not self._filter.accept(mid):
                due = [j for j, item in enumerate(self._queue)
                       if item[0] <= now]
                continue
            msg = dict(msg)
            msg["_wire_lag"] = now - ts
            return msg
        return None

    def has_ready(self) -> bool:
        if self._preload:
            return True
        now = time.perf_counter()
        return any(item[0] <= now for item in self._queue)

    def next_due(self) -> float | None:
        if not self._queue:
            return None
        return min(item[0] for item in self._queue)

    def drain(self) -> list[dict]:
        out = []
        while (msg := self.try_recv()) is not None:
            out.append(msg)
        return out

    def stop(self, join_timeout: float = 2.0) -> None:
        try:
            self.send({"kind": "stop"})
            if self.process is not None:
                self.process.join(join_timeout)
                if self.process.is_alive():
                    self.process.terminate()
                    self.process.join(join_timeout)
        except (OSError, ValueError):
            pass
        finally:
            self._detach()

    def kill(self) -> None:
        self.broken = True
        try:
            if self.process is not None and self.process.is_alive():
                self.process.terminate()
                self.process.join(1.0)
        except (OSError, ValueError):
            pass
        finally:
            self._detach()


class TcpHost:
    """The master's listener: accepts worker connections, validates the
    hello handshake, and attaches sockets to their registered links.

    A hello whose incarnation is *older* than the link's is refused and
    the socket closed — a zombie from before a respawn can never
    deliver into the current incarnation's stream."""

    def __init__(self, host: str = "127.0.0.1"):
        self._listener = socket.create_server((host, 0), backlog=64)
        self.addr = self._listener.getsockname()
        self._links: dict[int, TcpWorkerLink] = {}
        self._lock = threading.Lock()
        self._closing = False
        self.rejected_stale = 0
        self._thread = threading.Thread(target=self._accept_loop,
                                        daemon=True)
        self._thread.start()

    def register(self, link: TcpWorkerLink) -> None:
        with self._lock:
            self._links[link.worker_id] = link

    def _accept_loop(self) -> None:
        while not self._closing:
            try:
                sock, _ = self._listener.accept()
            except OSError:
                return
            try:
                self._handshake(sock)
            except (OSError, FrameError, pickle.UnpicklingError,
                    EOFError, ValueError):
                try:
                    sock.close()
                except OSError:
                    pass

    def _handshake(self, sock: socket.socket) -> None:
        sock.settimeout(5.0)
        decoder = FrameDecoder()
        frames: list = []
        while not frames:
            data = sock.recv(65536)
            if not data:
                raise EOFError("peer closed during handshake")
            frames = decoder.feed(data)
        payload, _mid, _ts = frames[0]
        hello = safe_loads(payload)
        if hello.get("kind") != HELLO_KIND:
            raise ValueError(f"expected hello, got {hello.get('kind')!r}")
        wid = int(hello["worker"])
        inc = int(hello.get("incarnation", 0))
        with self._lock:
            link = self._links.get(wid)
        if link is None or inc < link.incarnation or link.broken:
            self.rejected_stale += 1
            sock.close()                 # stale incarnation: refused
            return
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        sock.settimeout(None)
        link.attach(sock, decoder=decoder, pending=frames[1:])

    def close(self) -> None:
        self._closing = True
        try:
            self._listener.close()
        except OSError:
            pass
        self._thread.join(2.0)


def start_worker_tcp(
    host: TcpHost,
    worker_id: int,
    target,
    setup,
    *,
    incarnation: int = 0,
    fault=None,
    seed: int = 0,
    start_method: str = "spawn",
) -> TcpWorkerLink:
    """Spawn one worker that dials back into ``host`` over TCP; the
    returned link is already registered for the handshake."""
    import multiprocessing as mp

    link = TcpWorkerLink(worker_id, incarnation=incarnation,
                         fault=fault, seed=seed)
    host.register(link)
    ctx = mp.get_context(start_method)
    spec = (tuple(host.addr), worker_id, incarnation)
    proc = ctx.Process(target=tcp_child_main, args=(spec, target, setup),
                       daemon=True)
    proc.start()
    link.process = proc
    return link

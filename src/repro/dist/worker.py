"""Worker process: real per-chunk partial gradients + enacted faults.

``worker_main`` is the spawn target.  Each round message carries
executor-style mini-task items ``{"key", "chunks", "coeffs"}``; the
worker computes every referenced chunk gradient for real and returns
the coefficient-weighted combinations — exactly the quantities the
master's ``JobDecode`` weights reconstruct the full gradient from, for
all registered schemes (GC/SR-SGC ``ell`` rows, M-SGC ``d1``/``d2``
parts, clustered per-cluster codes, uncoded chunks).

Two compute modes, shared with the master through
:class:`TaskComputer` (the master instantiates the same class to form
the full-gradient truth its decode certificate checks against):

* ``linear`` (default) — closed-form least-squares chunk gradients
  ``g_c = X_c^T (X_c theta - y_c)`` on a deterministic per-job dataset;
  exact decode, no heavyweight imports in the child, fast enough that
  the *injected* delay dominates the measured round time.
* ``grad`` — the coded trainer's per-slot gradient path:
  ``jax.grad(train.coded.chunk_loss_sum)`` on deterministic
  ``data.token_batch`` chunks of a real (tiny) transformer LM, raveled
  to a flat vector.  Heavier (each child compiles its own jit), kept
  for the slow suite / example.

Fault enactment (``injection.FaultSpec``): the per-round delay from the
master's trace is burned before reporting; ``drop_rounds`` suppresses
first-attempt sends (the master's resend recovers the cached result);
``kill_after`` exits the process for good.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from .injection import FaultSpec, enact_delay


def linear_job_data(seed: int, job: int, num_rows: int, dim: int):
    """Deterministic per-job least-squares problem (X, y, theta)."""
    rng = np.random.default_rng([seed, 7919, job])
    X = rng.standard_normal((num_rows, dim))
    y = rng.standard_normal(num_rows)
    theta = rng.standard_normal(dim)
    return X, y, theta


class TaskComputer:
    """Chunk-gradient oracle shared by workers (per-task values) and
    the master (full-gradient decode certificate)."""

    def __init__(self, seed: int, compute: str, dim: int, num_rows: int,
                 bounds, model_cfg=None, batch_size: int = 0,
                 seq_len: int = 0):
        self.seed = seed
        self.compute = compute
        self.dim = dim
        self.num_rows = num_rows
        self.bounds = [tuple(b) for b in bounds]
        self._jobs: dict[int, tuple] = {}
        if compute == "grad":
            self._init_grad(model_cfg, batch_size, seq_len)
        elif compute != "linear":
            raise ValueError(f"unknown compute mode {compute!r}")

    # -- linear mode -----------------------------------------------------
    def _linear_data(self, job: int):
        if job not in self._jobs:
            if len(self._jobs) > 64:
                self._jobs.clear()
            self._jobs[job] = linear_job_data(
                self.seed, job, self.num_rows, self.dim
            )
        return self._jobs[job]

    # -- grad mode (train/coded.py per-slot gradient path) ---------------
    def _init_grad(self, model_cfg, batch_size: int, seq_len: int):
        import jax
        from jax.flatten_util import ravel_pytree

        from repro.train.coded import chunk_loss_sum, init_train_state

        if model_cfg is None or batch_size <= 0:
            raise ValueError("grad mode needs model_cfg and batch_size")
        self.cfg = model_cfg
        self.batch_size = batch_size
        self.seq_len = seq_len
        self._ravel = lambda tree: np.asarray(ravel_pytree(tree)[0])
        self._init_state = init_train_state
        self._grad_fn = jax.jit(
            jax.grad(lambda p, b: chunk_loss_sum(p, self.cfg, b))
        )

    def _grad_data(self, job: int):
        import jax

        from repro.data import token_batch

        if job not in self._jobs:
            if len(self._jobs) > 16:
                self._jobs.clear()
            params, _ = self._init_state(
                self.cfg, jax.random.PRNGKey(self.seed * 100003 + job)
            )
            batch = token_batch(
                self.seed, job, self.batch_size, self.seq_len,
                self.cfg.vocab_size,
            )
            self._jobs[job] = (params, batch)
        return self._jobs[job]

    # -- shared surface --------------------------------------------------
    def set_bounds(self, bounds) -> None:
        """Adopt a new chunk partition (master ``reconfig`` after the
        fleet shrank): per-job data is partition-independent, so only
        the slice table changes."""
        self.bounds = [tuple(b) for b in bounds]

    def chunk_grad(self, job: int, chunk: int) -> np.ndarray:
        lo, hi = self.bounds[chunk]
        if self.compute == "linear":
            X, y, theta = self._linear_data(job)
            Xc = X[lo:hi]
            return Xc.T @ (Xc @ theta - y[lo:hi])
        import jax

        params, batch = self._grad_data(job)
        cb = jax.tree.map(lambda a: a[lo:hi], batch)
        return self._ravel(self._grad_fn(params, cb))

    def value(self, item: dict) -> np.ndarray:
        """Coefficient-weighted combination of the item's chunk grads."""
        chunks = item["chunks"]
        coeffs = item["coeffs"]
        out = coeffs[0] * self.chunk_grad(item["job"], chunks[0])
        for c, w in zip(chunks[1:], coeffs[1:]):
            out = out + w * self.chunk_grad(item["job"], c)
        return out

    def warmup(self) -> None:
        """Pre-compile the grad-mode jit for every distinct chunk shape
        (workers call this before reporting ready, so compile cost never
        counts against round timeouts or round measurement)."""
        if self.compute != "grad":
            return
        seen = set()
        for c, (lo, hi) in enumerate(self.bounds):
            if hi - lo not in seen:
                seen.add(hi - lo)
                self.chunk_grad(1, c)

    def full_grad(self, job: int) -> np.ndarray:
        """Full-batch gradient (the master's decode truth)."""
        if self.compute == "linear":
            X, y, theta = self._linear_data(job)
            return X.T @ (X @ theta - y)
        import jax

        params, batch = self._grad_data(job)
        return self._ravel(self._grad_fn(params, batch))


@dataclass(frozen=True)
class WorkerSetup:
    """Everything a spawned worker needs (must stay picklable)."""

    worker_id: int
    seed: int
    compute: str = "linear"
    dim: int = 8
    num_rows: int = 64
    bounds: tuple = ()
    fault: FaultSpec = field(default_factory=FaultSpec)
    model_cfg: object = None
    batch_size: int = 0
    seq_len: int = 0

    def computer(self) -> TaskComputer:
        return TaskComputer(
            self.seed, self.compute, self.dim, self.num_rows, self.bounds,
            model_cfg=self.model_cfg, batch_size=self.batch_size,
            seq_len=self.seq_len,
        )


def _pong(conn, worker_id: int, msg: dict) -> bool:
    """Answer a liveness ping (piggybacked on the round protocol);
    returns False when the pipe is gone."""
    try:
        conn.send({"kind": "pong", "worker": worker_id,
                   "seq": msg.get("seq")})
        return True
    except (BrokenPipeError, EOFError, OSError):
        return False


def _enact_cancellable(conn, worker_id: int, t: int, seconds: float,
                       mode: str):
    """Burn the injected delay, but abandon it if the master has moved
    on to a later round — the protocol's task cancellation: a straggler
    whose result was not admitted stops wasting time on it.  Returns the
    interrupting message (later round / stop) or ``None`` when the full
    delay elapsed.  Same-round resends arriving mid-delay are absorbed
    (the single reply after the delay answers them), and liveness pings
    are answered inline so a slow worker is never mistaken for a dead
    one."""
    deadline = time.perf_counter() + seconds
    while True:
        remaining = deadline - time.perf_counter()
        if remaining <= 0:
            return None
        enact_delay(min(remaining, 0.005), mode)
        try:
            if conn.poll(0):
                nxt = conn.recv()
                if nxt.get("kind") == "ping":
                    if not _pong(conn, worker_id, nxt):
                        return {"kind": "stop"}
                    continue
                if nxt.get("kind") == "round" and int(nxt["t"]) <= t:
                    continue
                return nxt
        except (EOFError, OSError):
            return {"kind": "stop"}


def worker_main(conn, setup: WorkerSetup) -> None:
    """Spawn target: serve round messages until stopped or killed."""
    fault = setup.fault
    computer = setup.computer()
    computer.warmup()
    if fault.ready_delay > 0:
        time.sleep(fault.ready_delay)   # slow (re)join
    # readiness handshake: the master must not start round timeouts
    # while children are still paying interpreter/import/compile
    # start-up cost
    try:
        conn.send({"kind": "ready", "worker": setup.worker_id})
    except (BrokenPipeError, OSError):
        return
    cache: dict[int, tuple] = {}      # t -> (values, compute_s, delay_s)
    pending = None                    # message that cancelled a delay
    while True:
        if pending is not None:
            msg, pending = pending, None
        else:
            try:
                msg = conn.recv()
            except (EOFError, OSError):
                return
        kind = msg.get("kind")
        if kind == "stop":
            return
        if kind == "ping":
            if not _pong(conn, setup.worker_id, msg):
                return
            continue
        if kind == "reconfig":
            # the fleet shrank: adopt the survivors' chunk partition and
            # forget results keyed on the old one
            computer.set_bounds(msg["bounds"])
            computer.warmup()
            cache.clear()
            continue
        if kind != "round":
            continue
        t, attempt = int(msg["t"]), int(msg["attempt"])
        t_recv = time.perf_counter()
        # master->worker wire time: the transport stamps "_sent" on the
        # master clock at send (same perf_counter base on one host)
        wire_s = (t_recv - float(msg["_sent"])
                  if msg.get("_sent") is not None else None)
        if t in cache:
            # resend path: the result was computed on the first attempt
            # and only the message was lost — answer from the cache
            values, compute_s, delay_s = cache[t]
        else:
            t0 = time.perf_counter()
            values = [(it["key"], computer.value(it))
                      for it in msg["items"]]
            compute_s = time.perf_counter() - t0
            delay_s = float(msg["delay_s"])
            pending = _enact_cancellable(
                conn, setup.worker_id, t, delay_s, fault.delay_mode
            )
            if pending is not None:
                if pending.get("kind") == "stop":
                    return
                continue              # round cancelled by a newer one
            cache[t] = (values, compute_s, delay_s)
            for old in [k for k in cache if k < t - 4]:
                del cache[old]
        if not fault.drops(t, attempt):
            reply = {
                "kind": "result",
                "t": t,
                "attempt": attempt,
                "worker": setup.worker_id,
                "values": values,
                "telemetry": {
                    "recv": t_recv,
                    "delay_s": delay_s,
                    "compute_s": compute_s,
                    "wire_s": wire_s,
                    "sent": time.perf_counter(),
                },
            }
            try:
                conn.send(reply)
            except (BrokenPipeError, OSError):
                return
        if fault.dies_after(t):
            try:
                conn.close()
            finally:
                return

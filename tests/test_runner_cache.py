"""Host-side regression tests for the compiled-runner cache and the
``core.batch`` environment parsers (no jax required).

The runner cache is one FIFO shared by per-spec and per-bucket
compiled runners; "unsupported spec" verdicts are cached in a SIDE
table exempt from the ``REPRO_RUNNER_CACHE_CAP`` cap — a long mixed
sweep interleaving many unstageable specs with a few compiled ones
must never evict the hot compiled runners (the PR-5 regression).
"""

import warnings

import pytest

from repro.core import batch
from repro.core.batch import (
    _JAX_UNSUPPORTED,
    _fuse_enabled,
    _runner_cache_cap,
    _runner_cache_lookup,
    cache_stats,
    clear_runner_cache,
)


@pytest.fixture
def _clean_cache():
    clear_runner_cache()
    yield
    clear_runner_cache()


def test_unsupported_verdicts_exempt_from_cap(_clean_cache, monkeypatch):
    """Verdict entries must not count toward the FIFO cap nor evict
    compiled runners, and must still be cache hits on re-lookup."""
    monkeypatch.setenv("REPRO_RUNNER_CACHE_CAP", "2")
    _runner_cache_lookup(("spec", "a"), lambda: ("runner-a", "a"))
    _runner_cache_lookup(("spec", "b"), lambda: ("runner-b", "b"))
    # a long run of unsupported specs (pre-fix these filled the FIFO
    # and pushed both compiled runners out)
    for i in range(8):
        got = _runner_cache_lookup(
            ("spec", f"unsupported-{i}"), lambda: _JAX_UNSUPPORTED
        )
        assert got is _JAX_UNSUPPORTED
    st = cache_stats()
    assert st["size"] == 2          # both compiled runners still cached
    assert st["unsupported"] == 8   # verdicts tracked in the side table
    assert st["evictions"] == 0
    assert st["compiles"] == 2
    # the compiled runners are hits — build() must not run again
    hits0 = cache_stats()["hits"]
    assert _runner_cache_lookup(("spec", "a"), _fail)[0] == "runner-a"
    assert _runner_cache_lookup(("spec", "b"), _fail)[0] == "runner-b"
    # verdicts re-hit without re-deriving
    assert _runner_cache_lookup(("spec", "unsupported-0"), _fail) \
        is _JAX_UNSUPPORTED
    assert cache_stats()["hits"] == hits0 + 3


def _fail():  # pragma: no cover - called only on a cache-miss bug
    raise AssertionError("cache miss: build() re-ran for a cached key")


def test_compiled_runner_fifo_still_capped(_clean_cache, monkeypatch):
    """The cap still governs compiled runners themselves."""
    monkeypatch.setenv("REPRO_RUNNER_CACHE_CAP", "2")
    for i in range(4):
        _runner_cache_lookup(("spec", i), lambda i=i: (f"runner-{i}", ""))
    st = cache_stats()
    assert st["size"] == 2
    assert st["evictions"] == 2
    # FIFO: the two oldest runners were evicted
    rebuilt = []
    _runner_cache_lookup(("spec", 0), lambda: rebuilt.append(0) or ("r", ""))
    assert rebuilt == [0]


def test_clear_runner_cache_drops_verdicts(_clean_cache):
    _runner_cache_lookup(("spec", "u"), lambda: _JAX_UNSUPPORTED)
    assert cache_stats()["unsupported"] == 1
    clear_runner_cache()
    st = cache_stats()
    assert st["unsupported"] == 0 and st["size"] == 0
    assert st["hits"] == st["misses"] == 0


def test_runner_cache_cap_env_parser(monkeypatch):
    monkeypatch.delenv("REPRO_RUNNER_CACHE_CAP", raising=False)
    assert _runner_cache_cap() == batch._RUNNER_CACHE_CAP_DEFAULT
    monkeypatch.setenv("REPRO_RUNNER_CACHE_CAP", "7")
    assert _runner_cache_cap() == 7
    monkeypatch.setenv("REPRO_RUNNER_CACHE_CAP", "0")
    assert _runner_cache_cap() == 1          # clamped to >= 1
    monkeypatch.setenv("REPRO_RUNNER_CACHE_CAP", "not-an-int")
    with pytest.warns(UserWarning, match="REPRO_RUNNER_CACHE_CAP"):
        assert _runner_cache_cap() == batch._RUNNER_CACHE_CAP_DEFAULT


def test_grid_fuse_env_parser(monkeypatch):
    monkeypatch.delenv("REPRO_GRID_FUSE", raising=False)
    assert _fuse_enabled(None) is True
    # explicit per-call values bypass the env entirely
    assert _fuse_enabled(False) is False
    assert _fuse_enabled(True) is True
    for off in ("0", "false", "OFF", " no "):
        monkeypatch.setenv("REPRO_GRID_FUSE", off)
        assert _fuse_enabled(None) is False
    for on in ("1", "true", "ON", "yes", ""):
        monkeypatch.setenv("REPRO_GRID_FUSE", on)
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            assert _fuse_enabled(None) is True
    # the PR-5 regression: a typo'd value used to silently mean ON
    for typo in ("nope", "n0", "disable", "fuse=0"):
        monkeypatch.setenv("REPRO_GRID_FUSE", typo)
        with pytest.warns(UserWarning, match="REPRO_GRID_FUSE"):
            assert _fuse_enabled(None) is True

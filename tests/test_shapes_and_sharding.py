"""Input-shape specs, skip rules, and sharding-rule unit tests."""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, SHAPES, get_config, get_smoke, input_specs, skip_reason
from repro.launch.mesh import make_cpu_mesh
from repro.launch.sharding import param_pspec


def test_shapes_table():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288
    assert SHAPES["long_500k"].global_batch == 1


def test_skip_matrix():
    """Exactly the documented skips (DESIGN.md §Arch-applicability)."""
    skipped = {
        (a, s)
        for a in ARCHS
        for s in SHAPES
        if skip_reason(get_config(a), SHAPES[s])
    }
    expected = {
        ("hubert-xlarge", "decode_32k"),
        ("hubert-xlarge", "long_500k"),
        ("llama3.2-1b", "long_500k"),
        ("qwen2-0.5b", "long_500k"),
        ("qwen2-72b", "long_500k"),
        ("deepseek-67b", "long_500k"),
        ("paligemma-3b", "long_500k"),
        ("qwen2-moe-a2.7b", "long_500k"),
    }
    assert skipped == expected
    # 40 pairs total; 32 runnable
    assert len(ARCHS) * len(SHAPES) - len(skipped) == 32


@pytest.mark.parametrize("arch", ARCHS)
def test_input_specs_no_allocation(arch):
    cfg = get_config(arch)
    for name, shape in SHAPES.items():
        if skip_reason(cfg, shape):
            continue
        specs = input_specs(cfg, shape)
        for leaf in jax.tree.leaves(
            specs, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct)
        ):
            assert isinstance(leaf, jax.ShapeDtypeStruct), (arch, name, leaf)
        if shape.mode in ("train", "prefill"):
            b = jax.tree.leaves(specs["batch"])[0].shape[0]
            assert b == shape.global_batch
        else:
            assert specs["token"].shape == (shape.global_batch, 1)


def test_vlm_specs_include_prefix():
    cfg = get_config("paligemma-3b")
    specs = input_specs(cfg, "train_4k")
    assert specs["batch"]["prefix_embeds"].shape == (256, 256, 2048)
    # text + prefix = assigned seq_len
    assert specs["batch"]["tokens"].shape[1] + 256 == 4096


def test_audio_specs_are_frames():
    cfg = get_config("hubert-xlarge")
    specs = input_specs(cfg, "train_4k")
    assert specs["batch"]["frames"].shape == (256, 4096, 1280)


def test_param_pspec_rules():
    mesh = make_cpu_mesh(1, 1)  # single device; rules fall back cleanly

    class Leaf:
        def __init__(self, shape):
            self.shape = shape

    # megatron pattern: wq column, wo row — on a 1-wide model axis all
    # dims divide, so the preferred axes survive
    spec = param_pspec(("layers", "attn", "wq"), Leaf((2, 64, 128)), None, mesh)
    assert spec == P(None, None, "model")
    spec = param_pspec(("layers", "attn", "wo"), Leaf((2, 128, 64)), None, mesh)
    assert spec == P(None, "model", None)
    spec = param_pspec(("embed",), Leaf((1000, 64)), None, mesh)
    assert spec == P("model", None)
    spec = param_pspec(("layers", "norm1", "gamma"), Leaf((2, 64)), None, mesh)
    assert spec == P(None, None)


def test_param_pspec_divisibility_fallback():
    mesh = make_cpu_mesh(1, 1)

    class Leaf:
        def __init__(self, shape):
            self.shape = shape

    # a dim that does not divide the axis size gets replicated — with a
    # 1-sized axis everything divides, so emulate via a fake mesh shape
    import repro.launch.sharding as sh

    orig = sh._axis_size
    try:
        sh._axis_size = lambda mesh, axes: 16 if axes else 1
        spec = param_pspec(("layers", "attn", "wq"), Leaf((2, 64, 100)), None, mesh)
        assert spec == P(None, None, None)  # 100 % 16 != 0 -> replicate
    finally:
        sh._axis_size = orig


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_config_family_consistency(arch):
    full, smoke = get_config(arch), get_smoke(arch)
    assert full.family == smoke.family
    assert full.causal == smoke.causal
    assert full.frontend == smoke.frontend
    assert (full.num_experts > 0) == (smoke.num_experts > 0)
    assert (full.ssm_state > 0) == (smoke.ssm_state > 0)

"""Coded master-loop smoke: 2 jitted ``make_coded_train_step`` steps
per registered scheme on a tiny ModelConfig, certifying

* the decode-weight identity (weights summed per chunk == 1),
* coded gradient == uncoded full-batch gradient (gradient-level,
  ``aux_weight=0.0`` convention — Adam's first-step sign normalization
  amplifies sub-1e-6 grad noise into lr-sized param diffs, so params
  are NOT the thing to compare),
* straggler weight rows zero out cleanly,

plus a 2-step ``VectorizedCodedTrainer`` integration run and the
(slow-marked) multi-model coded-train bench smoke."""

import sys

sys.path.insert(0, ".")  # examples/benchmarks live at repo root

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from examples.multimodel_training import scheme_grid  # noqa: E402
from repro.configs.qwen2_0_5b import SMOKE  # noqa: E402
from repro.core import make_scheme  # noqa: E402
from repro.core.executor import conforming_pattern  # noqa: E402
from repro.data import coded_slot_batch, token_batch  # noqa: E402
from repro.models import loss_fn  # noqa: E402
from repro.train import VectorizedCodedTrainer  # noqa: E402
from repro.train.coded import chunk_loss_sum, make_coded_train_step  # noqa: E402

N, JOBS, BATCH, SEQ = 8, 2, 32, 16
CFG = SMOKE.replace(num_layers=1, d_model=64, num_heads=2,
                    num_kv_heads=1, head_dim=32, d_ff=128, vocab_size=128)
SPECS = scheme_grid(N)
# schemes whose job-t decode uses exactly round t's survivors, so the
# straggler-row-zeroing check can name the stragglers from the pattern
PER_ROUND = {"gc-rep", "gc", "dc-gc", "sb-gc"}


def _drive(label, name, kw, seed=3):
    """Step a scheme through a conforming pattern; return the scheme
    and {job: (JobDecode, straggler row at its decode round)}."""
    sch = make_scheme(name, N, JOBS + 4, **kw)
    rounds = JOBS + sch.T + 2
    pat = conforming_pattern(sch.design_model, rounds, N, seed=seed,
                             density=0.3)
    jds = {}
    for t in range(1, rounds + 1):
        sch.step(t, pat[t - 1])
        for jd in sch.collect_decodes(t):
            jds[jd.job] = (jd, pat[jd.round_done - 1])
    assert set(range(1, JOBS + 1)) <= set(jds), label
    return sch, jds


@jax.jit
def _uncoded_grad(params, batch):
    return jax.grad(
        lambda p: loss_fn(p, CFG, batch, aux_weight=0.0)
    )(params)


@jax.jit
def _coded_grad(params, coded, w):
    """grad of the weighted coded loss — vmapped over the flattened
    (n*slots) chunk axis so the graph stays one chunk-loss wide."""

    def loss(p):
        flat = jax.tree.map(
            lambda leaf: leaf.reshape((-1,) + leaf.shape[2:]), coded
        )
        losses = jax.vmap(lambda ch: chunk_loss_sum(p, CFG, ch))(flat)
        return jnp.sum(w.ravel() * losses) / BATCH

    return jax.grad(loss)(params)


@pytest.mark.parametrize("spec", SPECS, ids=lambda s: s[0])
def test_two_coded_steps_gradient_exact(spec):
    label, name, kw = spec
    sch, jds = _drive(label, name, kw)
    num_chunks, slots = sch.chunk_grid()
    assert BATCH % num_chunks == 0, label

    step = jax.jit(make_coded_train_step(
        CFG, sch.n, getattr(sch, "s", 0), lr=1e-3, num_chunks=num_chunks,
    ))

    from repro.train.coded import init_train_state

    params, opt = init_train_state(CFG, jax.random.PRNGKey(0))
    for job in range(1, JOBS + 1):
        jd, stragglers = jds[job]
        slot_map = sch.chunk_slots(job)
        w = sch.decode_weights(jd)

        # decode-weight identity: every chunk reconstructed with
        # total coefficient exactly 1
        acc = np.zeros(num_chunks)
        np.add.at(acc, slot_map.ravel(), w.ravel().astype(np.float64))
        np.testing.assert_allclose(acc, 1.0, atol=1e-5, err_msg=label)

        # straggler rows zero out cleanly
        if label in PER_ROUND:
            assert (w[stragglers] == 0).all(), label
        for i in range(N):
            contributes = (
                i in jd.ell_weights or i in jd.d1_workers
                or any(i in ws for ws in jd.group_weights.values())
            )
            if not contributes:
                assert (w[i] == 0).all(), (label, i)

        batch = token_batch(0, job, BATCH, SEQ, CFG.vocab_size)
        coded = coded_slot_batch(batch, slot_map, num_chunks)
        wj = jnp.asarray(w)

        # coded gradient == uncoded full-batch gradient, exactly
        ref = _uncoded_grad(params, batch)
        got = _coded_grad(params, coded, wj)
        for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(ref)):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), atol=2e-5, rtol=2e-3,
                err_msg=label,
            )

        # ... and the jitted train step consumes the same view: its
        # reported (coded) loss equals the uncoded full-batch loss at
        # the pre-update params, and it moves the params
        full_pre = float(loss_fn(params, CFG, batch, aux_weight=0.0))
        before = np.asarray(jax.tree.leaves(params)[0])
        params, opt, metrics = step(params, opt, coded, wj)
        assert float(metrics["loss"]) == pytest.approx(full_pre, abs=1e-4)
        assert not np.allclose(
            before, np.asarray(jax.tree.leaves(params)[0])
        ), label


def test_vectorized_trainer_two_steps():
    """End-to-end 2-job run of the kernel-path trainer: losses logged
    per model, every job decoded, clock advances."""
    sch = make_scheme("gc", N, 8, s=3)
    tr = VectorizedCodedTrainer(
        scheme=sch, cfg=CFG, num_models=2, batch_size=BATCH,
        seq_len=SEQ, lr=1e-3, seed=0,
    )
    delays = np.ones((8, N))
    delays[0, 5] = 40.0  # one hard straggler, within s=3 tolerance
    clock = tr.run(2, delays)
    assert clock > 0
    assert sorted(tr.job_done_time) == [1, 2]
    assert all(np.isfinite(tr.losses[m]).all() for m in range(2))
    assert len(tr.losses[0]) + len(tr.losses[1]) == 2


@pytest.mark.slow
def test_coded_train_bench_smoke():
    """The multi-model coded-training bench, smoke-sized (slow tier)."""
    from benchmarks.run import bench_coded_train

    bench_coded_train(n=8, models=2, jobs=8, smoke=True)

"""Pure-jnp oracle for the coded-combine kernel."""

import jax
import jax.numpy as jnp


def coded_combine(parts: jax.Array, weights: jax.Array) -> jax.Array:
    """weights @ parts computed in f32, cast back to parts.dtype."""
    acc = jnp.einsum(
        "k,kd->d",
        weights.astype(jnp.float32),
        parts.astype(jnp.float32),
    )
    return acc.astype(parts.dtype)


def coded_combine_tree(tree, weights):
    """Oracle for the pytree wrapper: combine leaf-wise."""
    return jax.tree.map(
        lambda leaf: jnp.einsum(
            "k,k...->...",
            weights.astype(jnp.float32),
            leaf.astype(jnp.float32),
        ).astype(leaf.dtype),
        tree,
    )

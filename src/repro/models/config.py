"""Model configuration covering all six assigned architecture families."""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // num_heads
    qkv_bias: bool = False
    rope_theta: float = 500_000.0
    sliding_window: int = 0     # 0 = full attention
    tie_embeddings: bool = False

    # MoE
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0           # per-expert hidden dim (0 -> d_ff)

    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 64

    # hybrid (zamba2-style): one *shared* attention block applied after
    # every ``attn_every`` SSM layers
    attn_every: int = 0

    # modality
    causal: bool = True          # False -> encoder-only (audio)
    frontend: str = "none"       # none | vision_stub | audio_stub
    num_prefix_tokens: int = 0   # patch embeddings prepended (vlm)

    dtype: str = "float32"
    remat: bool = True
    use_pallas: bool = False     # Pallas kernels (TPU target) vs jnp path
    # Unroll the layer scan.  XLA's cost_analysis counts a while-loop
    # body ONCE (not x trip-count), so the dry-run lowers an unrolled
    # twin of each step to get true per-step FLOPs/bytes/collectives.
    scan_unroll: bool = False
    # FSDP-style activation constraint: when non-empty, layer bodies pin
    # hidden states to P(act_batch_axes, act_seq_axis, None) so XLA
    # all-gathers the (sharded) params instead of psumming activations
    # (§Perf).  act_seq_axis="model" gives Megatron-style sequence
    # parallelism (long-sequence prefill where batch < mesh).
    act_batch_axes: tuple = ()
    act_seq_axis: str = ""
    # activation-checkpoint policy: "full" | "dots" | "none" (see §Perf)
    remat_policy: str = "full"
    source: str = ""             # citation for the config

    @property
    def head_dim_(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def has_decode(self) -> bool:
        """Encoder-only models have no autoregressive decode step."""
        return self.causal

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic path available (SSM/hybrid recurrence or SWA)."""
        return self.family in ("ssm", "hybrid") or self.sliding_window > 0

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    @property
    def expert_d_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # -- parameter counting (for roofline's 6*N*D) ------------------------
    def param_count(self, active_only: bool = False) -> int:
        d, dh = self.d_model, self.head_dim_
        n_attn_layers, n_ssm_layers = self._layer_split()
        attn = (
            d * (self.num_heads * dh)            # q
            + 2 * d * (self.num_kv_heads * dh)   # k, v
            + (self.num_heads * dh) * d          # o
        )
        if self.qkv_bias:
            attn += (self.num_heads + 2 * self.num_kv_heads) * dh
        mlp_dense = 3 * d * self.d_ff            # SwiGLU
        total = 0
        if self.family == "moe":
            e_ff = self.expert_d_ff
            routed = self.num_experts * 3 * d * e_ff
            active = self.num_experts_per_tok * 3 * d * e_ff
            shared = self.num_shared_experts * 3 * d * e_ff
            router = d * self.num_experts
            per_layer = attn + router + shared + (active if active_only else routed)
            total += self.num_layers * (per_layer + 2 * d)
        elif self.family in ("ssm", "hybrid"):
            di, st, nh = self.ssm_d_inner, self.ssm_state, self.ssm_heads
            # in_proj(z,x,B,C,dt) + out_proj + conv + A,D
            ssm_layer = (
                d * (2 * di + 2 * st + nh)
                + di * d
                + 4 * (di + 2 * st)
                + 2 * nh
                + d
            )
            total += n_ssm_layers * ssm_layer
            if self.family == "hybrid" and n_attn_layers:
                total += attn + mlp_dense + 2 * d  # ONE shared block
        else:
            total += self.num_layers * (attn + mlp_dense + 2 * d)
        total += self.vocab_size * d  # embedding
        if not self.tie_embeddings:
            total += self.vocab_size * d  # lm head
        total += d  # final norm
        return total

    def _layer_split(self) -> tuple[int, int]:
        if self.family == "hybrid":
            n_shared_calls = self.num_layers // max(self.attn_every, 1)
            return n_shared_calls, self.num_layers
        if self.family == "ssm":
            return 0, self.num_layers
        return self.num_layers, 0

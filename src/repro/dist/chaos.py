"""Chaos-campaign driver: composed fault scenarios with end-to-end
invariant checks.

A :class:`ChaosCampaign` composes per-worker :class:`FaultSpec`\\ s into
a timed scenario over the elastic harness — kill waves, correlated
regional outages, flapping workers, delayed rejoins — and
:func:`run_campaign` executes it and *audits* the result instead of
just returning it:

* every one of the J jobs decoded exactly (certificate vs the
  full-batch gradient);
* the run terminated without deadlock or un-budgeted abort;
* the telemetry stream is complete — one ledger record per attempted
  round, measured round times aligned, every committed round carrying
  its gate-admitted row, timestamps ordered;
* the supervision log shows the transitions the scenario was built to
  provoke (minimum respawn / rejoin / degrade counts).

Violations come back as human-readable strings on the
:class:`CampaignReport` rather than raising, so a campaign sweep can
report every broken invariant at once (the ``chaos`` bench and
``tests/test_dist_elastic.py`` assert ``report.passed``).

Builders (``kill_wave``, ``regional_outage``, ``flapping``,
``delayed_rejoin``) cover the canonical process-fault scenarios;
``partition_heal`` and ``lossy_network`` run on the TCP transport and
exercise the network-fault tier (``repro.dist.net``): partitions must
be told apart from deaths (healing with NO respawn burned) and a lossy
wire must never corrupt a decode.  Campaigns are plain dataclasses, so
bespoke ones are one literal away.  See ``docs/fault_tolerance.md``
for how each scenario exercises the supervision state machine.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .injection import FaultSpec, NetFaultSpec
from .master import HarnessConfig, HarnessResult, run_harness


@dataclass
class ChaosCampaign:
    """One composed fault scenario plus the invariants it must provoke."""

    name: str
    n: int
    jobs: int
    scheme: str = "gc"
    params: dict = field(default_factory=lambda: {"s": 1})
    faults: dict = field(default_factory=dict)          # wid -> FaultSpec
    respawn_faults: dict = field(default_factory=dict)  # respawned incarnation
    respawn_max_attempts: int = 3
    respawn_backoff_s: float = 0.2
    respawn_backoff_max_s: float = 1.0
    degrade: str = "off"
    expect_abort: bool = False
    transport: str = "pipe"                             # "pipe" | "tcp"
    net_faults: dict = field(default_factory=dict)      # wid -> NetFaultSpec
    min_respawns: int = 0
    min_rejoins: int = 0
    min_degrades: int = 0
    min_partitions: int = 0
    min_heals: int = 0
    max_respawns: int | None = None     # spurious-respawn ceiling
    note: str = ""
    config_kw: dict = field(default_factory=dict)       # extra HarnessConfig


@dataclass
class CampaignReport:
    campaign: str
    result: HarnessResult
    violations: list = field(default_factory=list)

    @property
    def passed(self) -> bool:
        return not self.violations

    def summary(self) -> dict:
        res = self.result
        return {
            "campaign": self.campaign,
            "passed": self.passed,
            "violations": list(self.violations),
            "rounds": res.ledger.rounds,
            "decoded": len(res.decoded_jobs),
            "jobs": res.J,
            "decode_max_err": res.decode_max_err,
            "deaths": res.deaths,
            "respawns": res.respawns,
            "rejoins": res.rejoins,
            "partitions": res.partitions,
            "heals": res.heals,
            "degraded": res.degraded,
            "aborted": res.aborted,
        }


# ---------------------------------------------------------------------------
# canonical scenario builders
# ---------------------------------------------------------------------------


def _bursty_defaults(n: int, kw: dict) -> dict:
    """Builders default to M-SGC's bursty design model (B=1): it admits
    a dead worker's row for exactly one round before the gate must wait
    it out, so the master deterministically BLOCKS on the rejoin — the
    supervision path these scenarios exist to provoke.  (Under GC-Rep
    a dead lane can stay admissible forever and a fast run may finish
    before any replacement reports ready.)"""
    kw.setdefault("scheme", "m-sgc")
    kw.setdefault("params", {"B": 1, "W": 3, "lam": n})
    return kw


def kill_wave(n: int, jobs: int, kills: dict, **kw) -> ChaosCampaign:
    """Workers die at different rounds (``kills``: wid -> round) and the
    respawn budget brings each one back clean."""
    kw = _bursty_defaults(n, kw)
    kw.setdefault("min_respawns", len(kills))
    kw.setdefault("min_rejoins", len(kills))
    return ChaosCampaign(
        name=kw.pop("name", "kill-wave"),
        n=n, jobs=jobs,
        faults={w: FaultSpec(kill_after=r) for w, r in kills.items()},
        note=f"kill {sorted(kills)} at rounds "
             f"{[kills[w] for w in sorted(kills)]}, respawn clean",
        **kw,
    )


def regional_outage(n: int, jobs: int, region, at_round: int,
                    **kw) -> ChaosCampaign:
    """A correlated outage: every worker in ``region`` dies in the same
    round (one failure domain), all respawn."""
    region = sorted(region)
    kw = _bursty_defaults(n, kw)
    kw.setdefault("min_respawns", len(region))
    kw.setdefault("min_rejoins", len(region))
    return ChaosCampaign(
        name=kw.pop("name", "regional-outage"),
        n=n, jobs=jobs,
        faults={w: FaultSpec(kill_after=at_round) for w in region},
        note=f"region {region} out at round {at_round}",
        **kw,
    )


def flapping(n: int, jobs: int, worker: int, first_kill: int,
             rekill_after: int, **kw) -> ChaosCampaign:
    """One worker dies, rejoins, and dies again — and again: EVERY
    respawned incarnation carries the same ``kill_after``, so from
    ``rekill_after`` on the worker serves exactly one round per respawn.
    The default budget is sized so the run can flap its way to the end
    (one attempt per remaining round) rather than exhausting mid-run."""
    kw = _bursty_defaults(n, kw)
    kw.setdefault("respawn_max_attempts", jobs + 8)
    kw.setdefault("min_respawns", 2)
    kw.setdefault("min_rejoins", 1)
    return ChaosCampaign(
        name=kw.pop("name", "flapping"),
        n=n, jobs=jobs,
        faults={worker: FaultSpec(kill_after=first_kill)},
        respawn_faults={worker: FaultSpec(kill_after=rekill_after)},
        note=f"worker {worker} flaps: dies at {first_kill}, "
             f"again at {rekill_after}",
        **kw,
    )


def delayed_rejoin(n: int, jobs: int, worker: int, at_round: int,
                   ready_delay: float, **kw) -> ChaosCampaign:
    """The replacement process is slow to report ready
    (``FaultSpec.ready_delay``), so the fleet runs short-handed for a
    while before the rejoin replay catches the worker up."""
    kw = _bursty_defaults(n, kw)
    kw.setdefault("min_respawns", 1)
    kw.setdefault("min_rejoins", 1)
    return ChaosCampaign(
        name=kw.pop("name", "delayed-rejoin"),
        n=n, jobs=jobs,
        faults={worker: FaultSpec(kill_after=at_round)},
        respawn_faults={worker: FaultSpec(ready_delay=ready_delay)},
        note=f"worker {worker} dies at {at_round}, "
             f"rejoin delayed {ready_delay}s",
        **kw,
    )


def partition_heal(n: int, jobs: int, worker: int, *, at_round: int = 3,
                   heal_s: float = 0.8, mode: str = "twoway",
                   **kw) -> ChaosCampaign:
    """One worker drops off the network mid-run and comes back: from
    ``at_round`` its TCP link goes dark (``mode`` picks whether the
    master->worker direction stays open) and heals ``heal_s`` seconds
    later.  The supervisor must classify it PARTITIONED (the process is
    alive), block the gate on the heal, and take the worker back via
    the open-round replay with ZERO respawns — the acceptance gate for
    partition-vs-death discrimination."""
    kw = _bursty_defaults(n, kw)
    kw.setdefault("min_partitions", 1)
    kw.setdefault("min_heals", 1)
    kw.setdefault("max_respawns", 0)
    kw.setdefault("respawn_max_attempts", 3)  # a budget exists — unused
    return ChaosCampaign(
        name=kw.pop("name", "partition-heal"),
        n=n, jobs=jobs,
        transport="tcp",
        net_faults={worker: NetFaultSpec(
            partition_round=at_round, heal_after_s=heal_s,
            partition_mode=mode,
        )},
        note=f"worker {worker} partitioned ({mode}) at round {at_round}, "
             f"heals after {heal_s}s; no respawn allowed",
        **kw,
    )


def lossy_network(n: int, jobs: int, *, latency_s: float = 0.015,
                  jitter_s: float = 0.01, drop_p: float = 0.05,
                  dup_p: float = 0.05, reorder_p: float = 0.1,
                  **kw) -> ChaosCampaign:
    """Every link is bad at once: added latency with jitter plus
    probabilistic drop / duplicate / reorder on every frame.  The
    timeout/resend tier plus mid-filter dedup must deliver every decode
    exactly despite the wire — the generic lossy-datacenter scenario."""
    kw.setdefault("scheme", "gc")
    kw.setdefault("params", {"s": 1})
    cfg_kw = dict(kw.pop("config_kw", {}))
    # drops eat both directions: give the resend tier budget to win
    cfg_kw.setdefault("max_retries", 4)
    cfg_kw.setdefault("round_timeout", 0.3)
    faults = {
        w: NetFaultSpec(latency_s=latency_s, latency_jitter_s=jitter_s,
                        drop_p=drop_p, dup_p=dup_p, reorder_p=reorder_p,
                        seed=w + 1)
        for w in range(n)
    }
    return ChaosCampaign(
        name=kw.pop("name", "lossy-network"),
        n=n, jobs=jobs,
        transport="tcp",
        net_faults=faults,
        config_kw=cfg_kw,
        note=f"all links lossy: +{latency_s * 1e3:.0f}ms(±{jitter_s * 1e3:.0f}) "
             f"drop={drop_p} dup={dup_p} reorder={reorder_p}",
        **kw,
    )


# ---------------------------------------------------------------------------
# execution + audit
# ---------------------------------------------------------------------------


def _delays_for(camp: ChaosCampaign, rounds: int,
                seed: int) -> np.ndarray:
    """Mild i.i.d. planned delays: enough texture that the mu-rule and
    gate stay exercised, small enough that the chaos (not the trace)
    dominates the run."""
    rng = np.random.default_rng([seed, camp.n, camp.jobs])
    delays = rng.uniform(0.0, 0.4, size=(rounds, camp.n))
    # an occasional genuine straggler spike
    spikes = rng.random((rounds, camp.n)) < 0.08
    delays[spikes] += rng.uniform(4.0, 8.0, size=int(spikes.sum()))
    return delays


def run_campaign(camp: ChaosCampaign, *, time_scale: float = 0.02,
                 seed: int = 1) -> CampaignReport:
    """Execute ``camp`` on the real harness and audit the invariants."""
    rounds = camp.jobs + 8
    delays = _delays_for(camp, rounds, seed)
    cfg_kw = dict(
        alpha=8.0,
        time_scale=time_scale,
        seed=seed,
        round_timeout=0.25,
        faults=dict(camp.faults),
        respawn_faults=dict(camp.respawn_faults),
        respawn_max_attempts=camp.respawn_max_attempts,
        respawn_backoff_s=camp.respawn_backoff_s,
        respawn_backoff_max_s=camp.respawn_backoff_max_s,
        degrade=camp.degrade,
        transport=camp.transport,
        net_faults=dict(camp.net_faults),
    )
    cfg_kw.update(camp.config_kw)   # campaign overrides win
    cfg = HarnessConfig(**cfg_kw)
    res = run_harness(camp.scheme, camp.n, camp.jobs, delays,
                      params=dict(camp.params), config=cfg)
    return CampaignReport(campaign=camp.name, result=res,
                          violations=_audit(camp, res))


def _audit(camp: ChaosCampaign, res: HarnessResult) -> list:
    v: list[str] = []
    if camp.expect_abort:
        if not res.aborted:
            v.append("expected the run to abort, but it completed")
        return v
    if res.aborted:
        v.append(f"aborted: {res.abort_reason}")
    want = set(range(1, camp.jobs + 1))
    missing = sorted(want - set(res.decoded_jobs))
    if missing:
        v.append(f"jobs never decoded: {missing}")
    if res.decode_max_err > 1e-6:
        v.append(f"decode error {res.decode_max_err:.2e} > 1e-6")
    led = res.ledger
    if led.rounds != len(res.round_times):
        v.append(
            f"telemetry gap: {led.rounds} ledger rounds vs "
            f"{len(res.round_times)} measured round times"
        )
    degrade_rounds = {ev.get("round") for ev in res.events
                      if ev.get("kind") == "degrade"}
    for rec in led.records:
        if rec.effective_row is None and rec.t not in degrade_rounds:
            v.append(f"round {rec.t}: no committed straggler row")
        for i, st in enumerate(rec.stats):
            if (st.reported is not None and st.sent is not None
                    and st.reported < st.sent):
                v.append(
                    f"round {rec.t} worker {i}: reported before sent"
                )
    if res.respawns < camp.min_respawns:
        v.append(f"respawns {res.respawns} < expected "
                 f">={camp.min_respawns}")
    if res.rejoins < camp.min_rejoins:
        v.append(f"rejoins {res.rejoins} < expected >={camp.min_rejoins}")
    if res.degraded < camp.min_degrades:
        v.append(f"degrades {res.degraded} < expected "
                 f">={camp.min_degrades}")
    if res.partitions < camp.min_partitions:
        v.append(f"partitions {res.partitions} < expected "
                 f">={camp.min_partitions}")
    if res.heals < camp.min_heals:
        v.append(f"heals {res.heals} < expected >={camp.min_heals}")
    if camp.max_respawns is not None and res.respawns > camp.max_respawns:
        v.append(f"spurious respawns: {res.respawns} > "
                 f"allowed {camp.max_respawns} (partition must heal, "
                 "not respawn)")
    return v

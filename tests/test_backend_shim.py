"""Unit tests for the ``core.backend`` shim: functional updates, the
``scan``/``jit`` staging hooks (with their numpy Python-loop
fallbacks), and the segment/argsort helpers, on every registered
backend."""

import numpy as np
import pytest

from repro.core.backend import available_backends, get_backend, xp_of

BACKENDS = [
    pytest.param(
        name,
        marks=()
        if name in available_backends()
        else pytest.mark.skip(reason=f"{name} backend not registered"),
    )
    for name in ("numpy", "jax")
]


@pytest.mark.parametrize("backend", BACKENDS)
def test_at_set_semantics(backend):
    bk = get_backend(backend)
    a = bk.xp.zeros((2, 3), dtype=bool)
    b = bk.at_set(a, (0, 1), True)
    c = bk.at_set(b, (slice(None), 2), True)
    assert np.asarray(c).tolist() == [
        [False, True, True],
        [False, False, True],
    ]


@pytest.mark.parametrize("backend", BACKENDS)
def test_at_or_bool_semantics(backend):
    """OR-update on bool arrays: True never reverts to False, False
    stays False unless or-ed with True (the single-scatter jax path
    must match numpy's ``|=`` exactly)."""
    bk = get_backend(backend)
    a = bk.xp.zeros((2, 3), dtype=bool)
    a = bk.at_set(a, (0, 0), True)
    val = bk.xp.asarray(np.array([[True, False, False],
                                  [False, True, False]]))
    out = bk.at_or(a, (slice(None), slice(None)), val)
    assert np.asarray(out).tolist() == [
        [True, False, False],
        [False, True, False],
    ]
    # or-ing False is a no-op on set bits
    out = bk.at_or(out, (slice(None), 0), False)
    assert np.asarray(out)[0, 0]


@pytest.mark.parametrize("backend", BACKENDS)
def test_at_or_int_semantics(backend):
    bk = get_backend(backend)
    a = bk.xp.asarray(np.array([[1, 2], [4, 8]], dtype=np.int64))
    out = bk.at_or(a, (slice(None), 0), 2)
    assert np.asarray(out).tolist() == [[3, 2], [6, 8]]


def test_jax_at_helpers_do_not_mutate():
    if "jax" not in available_backends():
        pytest.skip("jax backend not registered")
    bk = get_backend("jax")
    a = bk.xp.zeros((2, 3), dtype=bool)
    b = bk.at_set(a, (0, 1), True)
    assert not bool(a[0, 1]) and bool(b[0, 1])
    c = bk.at_or(b, (slice(None), 2), True)
    assert not bool(b[0, 2]) and bool(c[0, 2])


@pytest.mark.parametrize("backend", BACKENDS)
def test_scan_matches_python_loop(backend):
    """The scan hook follows the ``lax.scan`` contract: carry
    threading, ``(t, x)`` tuple xs, stacked pytree ys."""
    bk = get_backend(backend)
    xp = bk.xp
    ts = xp.arange(1, 6)
    xs = xp.asarray(np.arange(10.0).reshape(5, 2))

    def f(carry, tx):
        t, x = tx
        carry = carry + x.sum() * t
        return carry, (carry, x * 2)

    carry, (ys, doubled) = bk.scan(f, xp.asarray(0.0), (ts, xs))
    expect = 0.0
    rows = []
    for t in range(1, 6):
        expect += (2 * (t - 1) + (2 * (t - 1) + 1)) * t
        rows.append(expect)
    assert np.isclose(float(carry), expect)
    assert np.allclose(np.asarray(ys), rows)
    assert np.allclose(np.asarray(doubled), np.arange(10.0).reshape(5, 2) * 2)


@pytest.mark.parametrize("backend", BACKENDS)
def test_jit_hook_runs(backend):
    bk = get_backend(backend)

    def f(x):
        return x * 2 + 1

    g = bk.jit(f)
    assert np.allclose(np.asarray(g(bk.xp.arange(3.0))), [1.0, 3.0, 5.0])


@pytest.mark.parametrize("backend", BACKENDS)
def test_segment_sum(backend):
    bk = get_backend(backend)
    data = bk.xp.asarray(np.array([1.0, 2.0, 3.0, 4.0]))
    ids = bk.xp.asarray(np.array([0, 2, 0, 2]))
    out = bk.segment_sum(data, ids, 3)
    assert np.allclose(np.asarray(out), [4.0, 0.0, 6.0])


@pytest.mark.parametrize("backend", BACKENDS)
def test_argsort_stable(backend):
    bk = get_backend(backend)
    arr = bk.xp.asarray(np.array([[2.0, 1.0, 1.0, 0.5]]))
    order = bk.argsort_stable(arr, axis=1)
    assert np.asarray(order).tolist() == [[3, 1, 2, 0]]


@pytest.mark.parametrize("backend", BACKENDS)
def test_where_and_lax(backend):
    bk = get_backend(backend)
    out = bk.where(bk.xp.asarray(np.array([True, False])), 1.0, 2.0)
    assert np.allclose(np.asarray(out), [1.0, 2.0])
    if backend == "numpy":
        assert bk.lax is None
        assert bk.concrete
    else:
        assert bk.lax is not None
        assert not bk.concrete


def test_xp_of_dispatch():
    assert xp_of(np.zeros(3)) is np
    if "jax" in available_backends():
        bk = get_backend("jax")
        assert xp_of(bk.xp.zeros(3)) is bk.xp


@pytest.mark.parametrize("backend", BACKENDS)
def test_vmap_hook(backend):
    """The vmap hook maps a pytree-returning fn over a leading batch
    axis, with per-arg in_axes (None = broadcast) — the numpy
    Python-loop fallback must match jax.vmap semantics."""
    bk = get_backend(backend)
    xp = bk.xp
    A = np.arange(12.0).reshape(3, 4)
    b = np.array([1.0, 2.0, 3.0])

    def f(a, s, c):
        return {"sum": a.sum() + s, "prod": a * c}

    out = bk.vmap(f, in_axes=(0, 0, None))(
        xp.asarray(A), xp.asarray(b), xp.asarray(2.0)
    )
    assert np.allclose(np.asarray(out["sum"]), A.sum(axis=1) + b)
    assert np.allclose(np.asarray(out["prod"]), A * 2.0)


@pytest.mark.parametrize("backend", BACKENDS)
def test_vmap_hook_tuple_outputs(backend):
    bk = get_backend(backend)
    xp = bk.xp
    A = np.arange(6.0).reshape(2, 3)
    out = bk.vmap(lambda a: (a.min(), a + 1.0))(xp.asarray(A))
    assert np.allclose(np.asarray(out[0]), A.min(axis=1))
    assert np.allclose(np.asarray(out[1]), A + 1.0)

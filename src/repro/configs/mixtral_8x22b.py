"""mixtral-8x22b [moe] — 8 experts top-2, SWA [arXiv:2401.04088]."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    moe_d_ff=16384,
    vocab_size=32768,
    num_experts=8,
    num_experts_per_tok=2,
    sliding_window=4096,
    rope_theta=1_000_000.0,
    dtype="bfloat16",
    source="arXiv:2401.04088",
)

SMOKE = CONFIG.replace(
    name="mixtral-8x22b-smoke",
    num_layers=2,
    d_model=256,
    num_heads=8,
    num_kv_heads=2,
    head_dim=32,
    d_ff=512,
    moe_d_ff=512,
    vocab_size=512,
    num_experts=4,
    num_experts_per_tok=2,
    sliding_window=16,
    dtype="float32",
)
